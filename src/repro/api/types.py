"""Typed request/response envelope for the unified search API (DESIGN.md §9).

``SearchRequest``/``SearchResponse`` replace the raw-array/tuple contracts end
to end: the facade, the serving engine, the result cache (whose key includes
the dynamic-params bytes) and the sharded merges all speak these types. The
response carries provenance — which index epoch served it, whether it came
from the cache, and which compiled shape bucket ran — so a caller can audit
exactly how its answer was produced.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import numpy as np

from repro.core.config import DynamicParams


@dataclass(frozen=True)
class SearchRequest:
    """One sparse query: term ids + weights, optionally with a per-request
    ``DynamicParams`` override (k ≤ the program's k_max, μ, η, β). ``params``
    is None for "serve at the engine's defaults"."""

    tids: np.ndarray  # int [n_terms]
    weights: np.ndarray  # float [n_terms]
    params: Optional[DynamicParams] = None

    def __post_init__(self) -> None:
        object.__setattr__(self, "tids", np.asarray(self.tids, np.int32))
        object.__setattr__(self, "weights", np.asarray(self.weights, np.float32))
        if self.tids.shape != self.weights.shape or self.tids.ndim != 1:
            raise ValueError(
                f"SearchRequest wants 1-D tids/weights of equal length, got "
                f"{self.tids.shape} and {self.weights.shape}"
            )


@dataclass(frozen=True)
class SearchResponse:
    """Result of one request: top-k documents plus traversal + serving provenance.

    ``doc_ids``/``scores`` are [k] (the request's dynamic k), -1 / NEG where
    fewer than k documents survived. ``theta`` and the visit counters are None
    when the serving retriever does not report them (e.g. a bare (ids, scores)
    test retriever)."""

    doc_ids: np.ndarray  # int32 [k], -1 where no result
    scores: np.ndarray  # float32 [k]
    theta: Optional[float] = None  # round-0 pruning threshold
    n_superblocks_visited: Optional[int] = None
    n_blocks_scored: Optional[int] = None
    params: Optional[DynamicParams] = None  # the resolved dynamic point served
    epoch: int = 0  # index epoch that produced this result
    cache_hit: bool = False  # served from the result cache?
    bucket: Optional[Tuple[int, int]] = None  # (batch, nq) compiled shape that ran
    shard_candidates: Optional[np.ndarray] = field(default=None, repr=False)  # int32 [P] top-γ share per shard

    @property
    def k(self) -> int:
        return int(self.doc_ids.shape[0])
