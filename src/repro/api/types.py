"""Typed request/response envelope for the unified search API (DESIGN.md §9).

``SearchRequest``/``SearchResponse`` replace the raw-array/tuple contracts end
to end: the facade, the serving engine, the result cache (whose key includes
the dynamic-params bytes) and the sharded merges all speak these types. The
response carries provenance — which index epoch served it, whether it came
from the cache, and which compiled shape bucket ran — so a caller can audit
exactly how its answer was produced.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import numpy as np

from repro.core.config import DynamicParams

PRIORITIES = ("interactive", "batch")


@dataclass(frozen=True)
class SearchRequest:
    """One sparse query: term ids + weights, optionally with a per-request
    ``DynamicParams`` override (k ≤ the program's k_max, μ, η, β). ``params``
    is None for "serve at the engine's defaults".

    Serving-policy fields (DESIGN.md §10, all optional and inert outside the
    engine): ``deadline_ms`` is a relative deadline — if it expires while the
    request is queued, the engine fails the future fast with
    ``DeadlineExceeded`` and never scores it; ``tenant`` names the token
    bucket charged at admission; ``priority`` picks the queue lane
    (``interactive`` preempts ``batch`` at every collect step); ``request_id``
    tags the request for log/error correlation (the engine assigns one when
    None)."""

    tids: np.ndarray  # int [n_terms]
    weights: np.ndarray  # float [n_terms]
    params: Optional[DynamicParams] = None
    deadline_ms: Optional[float] = None  # relative; None = no deadline
    tenant: Optional[str] = None  # admission quota bucket; None = anonymous
    priority: str = "interactive"  # 'interactive' | 'batch' queue lane
    request_id: Optional[str] = None  # caller-supplied correlation id

    def __post_init__(self) -> None:
        object.__setattr__(self, "tids", np.asarray(self.tids, np.int32))
        object.__setattr__(self, "weights", np.asarray(self.weights, np.float32))
        if self.tids.shape != self.weights.shape or self.tids.ndim != 1:
            raise ValueError(
                f"SearchRequest wants 1-D tids/weights of equal length, got "
                f"{self.tids.shape} and {self.weights.shape}"
            )
        if self.deadline_ms is not None and self.deadline_ms <= 0:
            raise ValueError(
                f"deadline_ms must be > 0 (or None for no deadline), got {self.deadline_ms!r}"
            )
        if self.priority not in PRIORITIES:
            raise ValueError(
                f"unknown priority {self.priority!r}; expected one of {PRIORITIES}"
            )


@dataclass(frozen=True)
class SearchResponse:
    """Result of one request: top-k documents plus traversal + serving provenance.

    ``doc_ids``/``scores`` are [k] (the request's dynamic k), -1 / NEG where
    fewer than k documents survived. ``theta`` and the visit counters are None
    when the serving retriever does not report them (e.g. a bare (ids, scores)
    test retriever).

    ``degraded``/``params_served`` (DESIGN.md §10): True when the SLO
    controller walked this request down the degradation ladder; then
    ``params_served`` is the cheaper point actually scored (``params`` keeps
    the resolved point too — they are the same object — so existing callers
    reading ``params`` see what was served either way)."""

    doc_ids: np.ndarray  # int32 [k], -1 where no result
    scores: np.ndarray  # float32 [k]
    theta: Optional[float] = None  # round-0 pruning threshold
    n_superblocks_visited: Optional[int] = None
    n_blocks_scored: Optional[int] = None
    params: Optional[DynamicParams] = None  # the resolved dynamic point served
    epoch: int = 0  # index epoch that produced this result
    cache_hit: bool = False  # served from the result cache?
    bucket: Optional[Tuple[int, int]] = None  # (batch, nq) compiled shape that ran
    shard_candidates: Optional[np.ndarray] = field(default=None, repr=False)  # int32 [P] top-γ share per shard
    degraded: bool = False  # served below the requested/default quality point?
    params_served: Optional[DynamicParams] = None  # the point actually scored
    delta_seq: int = 0  # mutation sequence served (0 for an immutable index)

    @property
    def k(self) -> int:
        return int(self.doc_ids.shape[0])
