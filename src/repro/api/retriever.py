"""The ``Retriever`` facade: one construction ritual for every serving shape
(DESIGN.md §9).

    retr = Retriever.build(corpus)                      # index + local backend
    retr = Retriever.load("/path/to/index", shards=4)   # persisted, sharded
    resp = retr.search(SearchRequest(tids, ws))          # one query, typed
    resp = retr.search(SearchRequest(tids, ws, params=DynamicParams(k=100, beta=0.5)))
    eng  = retr.serve(max_batch=8, cache_size=1024)      # async bucketed engine
    retr.add([(tids, ws), ...]); retr.delete([doc_id])   # live mutation (§12)

The facade owns the static/dynamic boundary: ``StaticConfig`` picks the
compiled program (backend registry: local / sharded / shard_map / exact), the
paper's ``DynamicParams.recommended(k)`` zero-shot preset is the default
dynamic point, and any request may override it per call — zero recompiles.
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Union

import numpy as np

from repro.api.backends import get_backend
from repro.api.types import SearchRequest, SearchResponse
from repro.core.config import (
    DynamicParams,
    StaticConfig,
    recommended_static,
)
from repro.core.query import make_query_batch


def _corpus_arrays(corpus):
    """Accept a data.synthetic.Corpus (or anything with the same attrs) or a
    bare (doc_ptr, tids, ws, vocab) tuple."""
    if hasattr(corpus, "doc_ptr"):
        return corpus.doc_ptr, corpus.tids, corpus.ws, corpus.vocab
    doc_ptr, tids, ws, vocab = corpus[:4]
    return doc_ptr, tids, ws, vocab


def _nq_bucket(n: int) -> int:
    """Geometric nq padding so repeated searches of similar-length queries
    reuse one compiled shape (mirrors the serving ladder's nq rungs)."""
    nq = 16
    while nq < n:
        nq *= 2
    return nq


class Retriever:
    """Unified search facade over an LSP index and a registered backend.

    Construction: ``build`` (corpus -> index), ``load`` (persisted dir, single
    or sharded), or ``from_index`` (an ``LSPIndex`` / ``store.ShardedIndex`` /
    shard list you already have). The backend resolves automatically — 'local'
    for one device, 'sharded' when shards are requested or loaded, 'shard_map'
    when a mesh is given — or pass ``backend=`` explicitly (see
    ``api.backends.list_backends()``).
    """

    def __init__(self, backend_callable, *, index, static_cfg: StaticConfig,
                 defaults: DynamicParams, backend_name: str, vocab: int,
                 factory=None):
        self._backend = backend_callable
        self._factory = factory
        self.index = index
        self.static_cfg = static_cfg
        self.defaults = defaults
        self.backend_name = backend_name
        self.vocab = vocab
        self._corpus = None  # (doc_ptr, tids, ws) retained by build() for promotion
        self._build_cfg = None
        self._adapter = None  # serve.mutable.MutableRetrieverAdapter once promoted

    # ---- construction ----------------------------------------------------------

    @classmethod
    def from_index(
        cls,
        index,
        static_cfg: Optional[StaticConfig] = None,
        *,
        params: Optional[DynamicParams] = None,
        backend: Optional[str] = None,
        shards: int = 0,
        mesh=None,
        impl: str = "auto",
        ns_true: Optional[int] = None,
        **backend_kw,
    ) -> "Retriever":
        from repro.index.layout import LSPIndex

        stored_shards = len(index.shards) if hasattr(index, "shards") else 0
        # LSPIndex and store.ShardedIndex are NamedTuples — a "shard list" is
        # any sequence that is neither
        is_shard_list = isinstance(index, (list, tuple)) and not isinstance(
            index, LSPIndex
        ) and not stored_shards
        is_sharded = bool(stored_shards or shards or is_shard_list)
        if backend is None:
            backend = "shard_map" if (mesh is not None and is_sharded) else (
                "sharded" if is_sharded else "local"
            )
        if static_cfg is None:
            k = params.k if params is not None else DynamicParams.k
            # a bare shard list has no global count attribute; the per-shard sum
            # (>= the true NS because of tail padding) is a safe γ clamp
            ns = (
                ns_true
                or (sum(s.n_superblocks for s in index) if is_shard_list else index.n_superblocks)
            )
            static_cfg = recommended_static(k, n_superblocks=ns)
        defaults = (params or DynamicParams.recommended(static_cfg.k_max)).validate_for(static_cfg)
        make = get_backend(backend)
        kw = dict(
            shards=shards or stored_shards, mesh=mesh, impl=impl,
            defaults=defaults, ns_true=ns_true, **backend_kw,
        )

        def factory(ix):
            """Rebuild the backend over a fresh index — the hot-swap hook the
            serving engine's ``swap_index`` uses."""
            return make(ix, static_cfg, **kw)

        meta = index.shards[0] if stored_shards else (
            index[0] if is_shard_list else index
        )
        return cls(
            make(index, static_cfg, **kw),
            index=index,
            static_cfg=static_cfg,
            defaults=defaults,
            backend_name=backend,
            vocab=meta.vocab,
            factory=factory,
        )

    @classmethod
    def build(
        cls,
        corpus,
        static_cfg: Optional[StaticConfig] = None,
        *,
        build_cfg=None,
        params: Optional[DynamicParams] = None,
        backend: Optional[str] = None,
        shards: int = 0,
        mesh=None,
        impl: str = "auto",
        **backend_kw,
    ) -> "Retriever":
        """Build an index over ``corpus`` (a ``data.synthetic.Corpus`` or a
        (doc_ptr, tids, ws, vocab) tuple) and wrap it in a backend."""
        from repro.index.builder import IndexBuildConfig, build_index

        doc_ptr, tids, ws, vocab = _corpus_arrays(corpus)
        bcfg = build_cfg or IndexBuildConfig()
        index = build_index(doc_ptr, tids, ws, vocab, bcfg)
        retr = cls.from_index(
            index, static_cfg, params=params, backend=backend, shards=shards,
            mesh=mesh, impl=impl, **backend_kw,
        )
        # retain the source corpus: mutable() promotion then starts from the
        # exact floats instead of the dequantized forward-index reconstruction
        retr._corpus = (np.asarray(doc_ptr), np.asarray(tids), np.asarray(ws))
        retr._build_cfg = bcfg
        return retr

    @classmethod
    def load(
        cls,
        directory: str,
        static_cfg: Optional[StaticConfig] = None,
        *,
        params: Optional[DynamicParams] = None,
        backend: Optional[str] = None,
        shards: Optional[int] = None,
        mesh=None,
        impl: str = "auto",
        mmap: bool = True,
        **backend_kw,
    ) -> "Retriever":
        """Open a persisted index (``repro.index.store`` format — single,
        sharded or mutable, auto-detected) mmap-backed and wrap it in a
        backend. A sharded directory yields the sharded backend at its stored
        shard count; ``shards=`` re-shards a *single*-index directory in
        memory. A mutable directory (``save_mutable_index``) comes back
        already promoted: its delta segment, tombstones and id counters are
        restored, so ``add``/``delete``/``compact`` resume where the save
        left off."""
        from repro.index.store import (
            MUTABLE_MANIFEST_FORMAT,
            load_index_auto,
            load_mutable_index,
            manifest_format,
        )

        if manifest_format(directory) == MUTABLE_MANIFEST_FORMAT:
            if shards or mesh is not None:
                raise ValueError(
                    f"{directory} is a mutable-index save; it serves single-device "
                    f"(delta merge is host-side) — drop shards=/mesh=, or compact "
                    f"and re-save with save_sharded_index for sharded serving"
                )
            mi = load_mutable_index(directory, mmap=mmap, device=True)
            retr = cls.from_index(
                mi.state().main, static_cfg, params=params,
                backend=backend or "local", impl=impl, **backend_kw,
            )
            from repro.serve.mutable import MutableRetrieverAdapter

            retr._build_cfg = mi.build_cfg
            mi.set_runtime(retr._backend)
            retr._adapter = MutableRetrieverAdapter(mi, retr._factory)
            retr._backend = retr._adapter
            retr.index = mi
            return retr

        index = load_index_auto(directory, mmap=mmap, device=True)
        stored = len(index.shards) if hasattr(index, "shards") else 0
        if stored and shards and shards != stored:
            raise ValueError(
                f"{directory} stores a {stored}-shard index; cannot serve it as "
                f"shards={shards} — re-save with save_sharded_index or drop shards="
            )
        return cls.from_index(
            index, static_cfg, params=params, backend=backend,
            shards=0 if stored else (shards or 0), mesh=mesh, impl=impl, **backend_kw,
        )

    # ---- search ----------------------------------------------------------------

    def search(self, request: Union[SearchRequest, tuple]) -> SearchResponse:
        """Synchronous single-query search. ``request.params`` overrides the
        zero-shot defaults without recompiling anything."""
        if not isinstance(request, SearchRequest):
            request = SearchRequest(*request)
        return self.search_batch([request])[0]

    def search_batch(self, requests: Sequence[SearchRequest]) -> List[SearchResponse]:
        """One batched call through the backend; per-request ``DynamicParams``
        mix freely within the batch (they ride as per-row traced arrays)."""
        requests = [
            r if isinstance(r, SearchRequest) else SearchRequest(*r) for r in requests
        ]
        row_params = [(r.params or self.defaults).validate_for(self.static_cfg) for r in requests]
        nq = _nq_bucket(max((len(r.tids) for r in requests), default=1))
        qb = make_query_batch(
            [(r.tids, r.weights) for r in requests], self.vocab, nq_max=nq
        )
        out = self._backend(qb, row_params)
        ids = np.asarray(out.doc_ids)
        scores = np.asarray(out.scores)
        theta = np.asarray(out.theta) if out.theta is not None else None
        nsb = np.asarray(out.n_superblocks_visited)
        nblk = np.asarray(out.n_blocks_scored)
        shard_cand = getattr(out, "shard_candidates", None)
        shard_cand = None if shard_cand is None else np.asarray(shard_cand)
        served_seq = int(getattr(out, "delta_seq", 0) or 0)
        bucket = (len(requests), nq)
        return [
            SearchResponse(
                doc_ids=ids[i, : row_params[i].k].copy(),
                scores=scores[i, : row_params[i].k].copy(),
                theta=None if theta is None else float(theta[i]),
                n_superblocks_visited=int(nsb[i]),
                n_blocks_scored=int(nblk[i]),
                params=row_params[i],
                epoch=0,
                cache_hit=False,
                bucket=bucket,
                shard_candidates=None if shard_cand is None else shard_cand[i].copy(),
                delta_seq=served_seq,
            )
            for i in range(len(requests))
        ]

    # ---- live mutation (DESIGN.md §12) ------------------------------------------

    def mutable(self) -> "Retriever":
        """Promote this retriever to a live-mutable one (idempotent, in place).

        The backend is wrapped in a ``serve.mutable.MutableRetrieverAdapter``
        over a ``MutableIndex``: adds land in an exactly-scored delta segment,
        deletes become tombstones, and ``compact()`` folds both back into
        superblocks. Searches keep flowing through the same facade/engine
        contract. ``build()`` retains the source corpus, so promotion is exact;
        a retriever over a loaded single index reconstructs its corpus from the
        forward index (dequantized — see ``index.mutable.corpus_from_index``).
        A persisted *sharded* set cannot be promoted in place: its source
        corpus is not recoverable shard-wise — load the single-index directory
        or rebuild from the corpus."""
        if self._adapter is not None:
            return self
        from repro.index.builder import IndexBuildConfig
        from repro.index.layout import LSPIndex
        from repro.index.mutable import MutableIndex, corpus_from_index
        from repro.serve.mutable import MutableRetrieverAdapter

        main = self.index if isinstance(self.index, LSPIndex) else None
        if self._corpus is not None:
            doc_ptr, tids, ws = self._corpus
        elif main is not None:
            doc_ptr, tids, ws = corpus_from_index(main)
        else:
            from repro.index.store import ShardedPromotionError

            raise ShardedPromotionError(
                "mutable() promotion of a sharded retriever",
                "the source corpus is not recoverable shard-wise; Retriever.load "
                "the single-index directory (the unsharded save) or "
                "Retriever.build from the corpus, promote THAT, and serve it "
                "with backend='sharded'",
            )
        mi = MutableIndex(
            main, doc_ptr, tids, ws, self.vocab,
            self._build_cfg or IndexBuildConfig(),
            runtime=self._backend,
        )
        self._adapter = MutableRetrieverAdapter(mi, self._factory)
        self._backend = self._adapter
        self.index = mi
        return self

    def add(self, docs) -> list[int]:
        """Add docs (each a ``(tids, weights)`` pair) to the live corpus;
        returns their assigned external ids. Promotes to mutable on first use.
        New docs are visible to every subsequent search (exactly scored from
        the delta segment until the next compaction)."""
        self.mutable()
        ids, _ = self._adapter.add_docs(docs)
        return ids

    def delete(self, ids) -> None:
        """Tombstone external doc ids — they never appear in results again.
        Raises KeyError on unknown/already-deleted ids."""
        self.mutable()
        self._adapter.delete_docs(ids)

    def compact(self) -> None:
        """Fold main + delta − tombstones into a fresh superblock generation
        (synchronous; serving engines attach a background CompactionManager
        instead — see ``serve()``)."""
        self.mutable()
        self._adapter.compact()

    def save(self, directory: str) -> str:
        """Persist the current state to ``directory`` (atomic commit). A
        promoted retriever writes the mutable format — main generation plus
        live delta/tombstone state, so ``Retriever.load`` resumes mutation
        exactly where this save left off; an unpromoted one writes the plain
        single-index format. Returns the content fingerprint."""
        from repro.index.layout import LSPIndex
        from repro.index.store import (
            ShardedPromotionError,
            save_index,
            save_mutable_index,
        )

        if self._adapter is not None:
            return save_mutable_index(directory, self.index, self._build_cfg)
        if not isinstance(self.index, LSPIndex):
            raise ShardedPromotionError(
                "Retriever.save of a sharded retriever",
                "persist the shard set with "
                "repro.index.store.save_sharded_index(directory, index, n_shards) "
                "from the original single LSPIndex, or save() a retriever loaded "
                "from the unsharded directory",
            )
        return save_index(directory, self.index, self._build_cfg)

    # ---- serving ----------------------------------------------------------------

    def serve(self, *, compaction=None, **engine_knobs):
        """Wrap this retriever in the async bucketed serving engine (DESIGN.md
        §6): batching, shape buckets, result cache (keyed on the dynamic-params
        bytes), failure isolation and ``swap_index`` hot-swaps all compose.

        When the retriever has been promoted with ``mutable()``, a background
        ``CompactionManager`` is attached (thresholds via
        ``compaction=dict(max_delta_docs=..., max_tombstones=..., interval_s=...)``;
        ``compaction=False`` serves without one) and the engine exposes
        ``add_docs``/``delete_docs``."""
        from repro.serve.engine import RetrievalEngine

        engine = RetrievalEngine(
            self._backend,
            self.vocab,
            default_params=self.defaults,
            retriever_factory=self._factory,
            **engine_knobs,
        )
        if self._adapter is not None and compaction is not False:
            from repro.serve.mutable import CompactionManager

            CompactionManager(engine, self._adapter, **(compaction or {}))
        return engine

    # ---- introspection -----------------------------------------------------------

    def n_traces(self) -> int:
        """Compiled-trace count of the backend (one per (Q, nq) shape; a
        dynamic sweep must not grow it — see the zero-recompilation tests)."""
        fn = getattr(self._backend, "n_traces", None)
        return fn() if fn else 0

    def warmup(self, shapes) -> None:
        self._backend.warmup(shapes)

    def __repr__(self) -> str:
        return (
            f"Retriever(backend={self.backend_name!r}, static={self.static_cfg}, "
            f"defaults={self.defaults})"
        )
