"""repro.api — the unified search API (DESIGN.md §9).

One facade (``Retriever``), one typed envelope (``SearchRequest`` /
``SearchResponse``), one config boundary (``StaticConfig`` compiles,
``DynamicParams`` is per-request — zero recompiles across a sweep), and a
backend registry (local / sharded / shard_map / exact) behind it all.

    from repro.api import Retriever, SearchRequest, DynamicParams

    retr = Retriever.build(corpus)
    resp = retr.search(SearchRequest(tids, weights))
    resp = retr.search(SearchRequest(tids, weights, params=DynamicParams(k=5, beta=0.5)))
    eng  = retr.serve(max_batch=8)          # async engine; eng.search(...) -> Future

``__all__`` is the public surface, pinned by tests/api_manifest.txt (CI fails
on drift).
"""

from repro.api.backends import get_backend, list_backends, register_backend
from repro.api.retriever import Retriever
from repro.api.types import SearchRequest, SearchResponse
from repro.core.config import (
    ConfigError,
    DynamicParams,
    RetrievalConfig,
    StaticConfig,
    combine,
    recommended,
    recommended_static,
)
__all__ = [
    "ConfigError",
    "DynamicParams",
    "RetrievalConfig",
    "RetrievalEngine",
    "Retriever",
    "SearchRequest",
    "SearchResponse",
    "StaticConfig",
    "combine",
    "get_backend",
    "list_backends",
    "recommended",
    "recommended_static",
    "register_backend",
]


def __getattr__(name):
    # lazy: repro.serve.engine itself imports repro.api.types (the envelope),
    # so an eager import here would be circular
    if name == "RetrievalEngine":
        from repro.serve.engine import RetrievalEngine

        return RetrievalEngine
    raise AttributeError(f"module 'repro.api' has no attribute {name!r}")
