"""Pytree helpers shared by optimizer / checkpoint / trainer layers."""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def tree_zeros_like(tree: Any) -> Any:
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a: Any, b: Any) -> Any:
    return jax.tree.map(jnp.add, a, b)


def tree_scale(tree: Any, s) -> Any:
    return jax.tree.map(lambda x: x * s, tree)


def tree_dot(a: Any, b: Any) -> jnp.ndarray:
    leaves = jax.tree.map(lambda x, y: jnp.vdot(x, y), a, b)
    return jax.tree.reduce(jnp.add, leaves, jnp.zeros((), jnp.float32))


def global_norm(tree: Any) -> jnp.ndarray:
    sq = jax.tree.map(lambda x: jnp.sum(jnp.square(x.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq, jnp.zeros((), jnp.float32)))


def param_count(tree: Any) -> int:
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(tree)))


def param_bytes(tree: Any) -> int:
    return int(sum(np.prod(x.shape) * x.dtype.itemsize for x in jax.tree.leaves(tree)))


def tree_cast(tree: Any, dtype) -> Any:
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree
    )


def flatten_with_paths(tree: Any) -> dict[str, Any]:
    """Flatten a pytree into {'a/b/0': leaf} for checkpointing."""
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        out[key] = leaf
    return out


def _path_str(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return str(p.idx)
    if isinstance(p, jax.tree_util.GetAttrKey):
        return str(p.name)
    return str(p)
