"""Minimal functional parameter-pytree "module" utilities (no flax in this container).

Conventions used across ``repro.models``:
  * a module is ``init(key, cfg, ...) -> params`` plus ``apply(params, cfg, x, ...)``;
  * params are nested dicts of jnp arrays, checkpoint/shard friendly;
  * initializers follow standard fan-in scaling and take explicit dtypes so that
    bf16-compute / fp32-master-weight policies live in the trainer, not the model.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def split_keys(key: jax.Array, n: int) -> Sequence[jax.Array]:
    return jax.random.split(key, n)


def dense_init(key, in_dim: int, out_dim: int, dtype=jnp.float32, scale: float | None = None):
    """Fan-in scaled truncated-normal weight (LeCun-ish; matches common LM practice)."""
    std = scale if scale is not None else in_dim**-0.5
    w = jax.random.truncated_normal(key, -2.0, 2.0, (in_dim, out_dim), jnp.float32) * std
    return w.astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32, std: float = 0.02):
    e = jax.random.truncated_normal(key, -2.0, 2.0, (vocab, dim), jnp.float32) * std
    return e.astype(dtype)


def zeros(shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)


def ones(shape, dtype=jnp.float32):
    return jnp.ones(shape, dtype)


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMS reduction in f32, normalization multiply in the activation dtype — the
    f32 full-activation copy of the naive formulation dominated prefill temp memory
    (15+ live f32[B,S,D] buffers; see EXPERIMENTS.md §Perf)."""
    ms = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(ms + eps).astype(x.dtype)
    return x * inv * gamma.astype(x.dtype)


def layer_norm(x: jax.Array, gamma: jax.Array, beta: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(dt)


def swiglu(x_gate: jax.Array, x_up: jax.Array) -> jax.Array:
    return jax.nn.silu(x_gate) * x_up


def gelu(x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x, approximate=True)


def ambient_axis_size(name: str) -> int:
    """Size of a mesh axis in the ambient mesh context (1 when absent)."""
    from jax._src.mesh import thread_resources

    mesh = thread_resources.env.physical_mesh
    if mesh.empty or name not in mesh.axis_names:
        return 1
    return mesh.shape[name]


def maybe_shard(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint iff an ambient mesh is active (no-op in unit tests).

    Axis names in `spec` that the ambient mesh lacks are dropped, so model code can
    annotate with the full ("pod","data","model") vocabulary and still run on small
    test meshes.
    """
    from jax._src.mesh import thread_resources
    from jax.sharding import PartitionSpec

    mesh = thread_resources.env.physical_mesh
    if mesh.empty:
        return x

    def filt(part):
        if part is None:
            return None
        parts = part if isinstance(part, tuple) else (part,)
        kept = tuple(p for p in parts if p in mesh.axis_names)
        return kept if kept else None

    return jax.lax.with_sharding_constraint(x, PartitionSpec(*[filt(s) for s in spec]))
