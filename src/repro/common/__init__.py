from repro.common.registry import Registry
from repro.common import tree_utils
